"""Wave scheduler + plan autotuner (repro.nmc.schedule, DESIGN.md §14).

* **Chunk-vector properties** (hypothesis, or the deterministic vendored
  shim when it is absent): arbitrary valid split points — word-aligned or
  not, with and without slide halos — gather bit-exactly vs the
  single-tile oracle, at every SEW and on both engines.
* **Plan registry**: cache hits return the *identical* SchedulePlan
  object across re-traces with fresh values; the key is structural.
* **Uniform-mode regression**: the cost model places the ragged tail /
  picks the remainder spread — an uneven matmul models strictly fewer
  wave cycles than the seed planner's ceil-packed tail-last behavior.
* **Autotuning**: tuned plans are bit-exact vs uniform (sync + async)
  and never model more cycles; the heterogeneous qrelu tape dispatches a
  genuinely mixed Caesar+Carus wave through one launch.
"""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro import nmc
from repro.core import alu, programs
from repro.nmc import partition as P
from repro.nmc import schedule as S

SEWS = (8, 16, 32)
RNG = np.random.default_rng(13)

# one shared runtime for the module: every executed wave shares a jit cache
_RT = nmc.NmcRuntime()


def _rand(shape, sew, rng=RNG):
    info = np.iinfo(alu.NP_DTYPES[sew])
    return rng.integers(info.min, info.max + 1, shape,
                        dtype=alu.NP_DTYPES[sew])


def _random_chunks(rng, total, tiles):
    """A random valid chunk vector: positive entries summing to ``total``,
    at most ``tiles`` of them, arbitrary (non-word-aligned) split points."""
    n = int(rng.integers(1, min(tiles, total) + 1))
    cuts = sorted(rng.choice(np.arange(1, total), size=n - 1,
                             replace=False).tolist()) if n > 1 else []
    edges = [0] + list(cuts) + [total]
    return tuple(int(b - a) for a, b in zip(edges, edges[1:]))


# ---------------------------------------------------------------------------
# Chunk-vector properties (planner level)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(4, 400), st.integers(1, 9), st.sampled_from(SEWS),
       st.integers(0, 3), st.integers(0, 2 ** 31))
def test_arbitrary_chunk_vectors_gather_bit_exact(n, tiles, sew, amount,
                                                  seed):
    """Any valid chunk vector — including ragged, non-word-aligned split
    points and slide read-ahead — partitions the stores exactly and the
    gathered shard oracles equal the single-tile oracle bit-for-bit."""
    rng = np.random.default_rng(seed)
    x, y = _rand(n, sew, rng), _rand(n, sew, rng)

    def kfn(t, x, y):
        v = t.load(x, bank=0)
        if amount:
            v = nmc.mac(v.slide_down(amount), 2, v)
        t.store((v * 3 + t.load(y)).max(0))

    b = nmc.jit(kfn, sew=sew).trace(x, y)
    chunks = _random_chunks(rng, n, tiles)
    pl = P.plan(b, tiles, partition="axis", chunks=chunks)
    assert pl.n_shards == len(chunks)
    assert (pl.oracle() == b.oracle()).all()
    # the partition-safety verifier accepts every valid skewed plan
    rep = nmc.verify_plan(b, pl)
    assert not rep.errors, rep.render()


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(("caesar", "carus")), st.sampled_from(SEWS),
       st.integers(0, 2), st.integers(0, 2 ** 31))
def test_user_schedule_plans_execute_bit_exact(engine, sew, amount, seed):
    """A user-supplied SchedulePlan with random skewed chunks executes
    bit-exactly vs the traced oracle on both engines at every SEW."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(16, 120))
    x = _rand(n, sew, rng)

    def kfn(t, x):
        v = t.load(x)
        if amount:
            v = nmc.mac(v.slide_down(amount), 2, v)
        t.store((v * 3 + 1).max(0))

    tiles = 4
    chunks = _random_chunks(rng, n, tiles)
    splan = S.SchedulePlan("axis", chunks, (engine,) * len(chunks),
                           tuple(range(len(chunks))), tiles, sew,
                           0.0, 0.0, 0.0, "user")
    ck = nmc.jit(kfn, sew=sew, tiles=tiles, runtime=_RT, schedule=splan)
    assert np.array_equal(ck(x), ck.oracle(x))


# ---------------------------------------------------------------------------
# Plan registry
# ---------------------------------------------------------------------------

def test_plan_cache_hit_returns_identical_object():
    """The registry key is the value-independent tape structure: re-calls
    with fresh activation values hit the cache and return the *same*
    SchedulePlan object; a different policy or structure misses."""
    S.clear_plan_cache()

    def kfn(t, x):
        t.store((t.load(x) * 3 + 1).max(0))

    ck = nmc.jit(kfn, tiles=4, runtime=_RT)
    b1 = ck.trace(_rand(100, 8))
    b2 = ck.trace(_rand(100, 8))          # same structure, fresh values
    p1 = S.plan_wave(b1, 4, mode="auto")[0]
    p2 = S.plan_wave(b2, 4, mode="auto")[0]
    assert p1 is p2
    # policy and structure are part of the key
    assert S.plan_wave(b1, 4, mode="uniform")[0] is not p1
    b3 = ck.trace(_rand(96, 8))           # different length: new structure
    assert S.plan_wave(b3, 4, mode="auto")[0] is not p1


def test_plan_cache_is_bounded_lru():
    S.clear_plan_cache()

    def kfn_of(n):
        def kfn(t, x):
            t.store(t.load(x) + 1)
        return kfn

    for i in range(S._PLAN_CAP + 8):
        b = nmc.jit(kfn_of(i)).trace(_rand(8 + i, 8))
        S.plan_wave(b, 2, mode="uniform")
    assert len(S._plan_cache) == S._PLAN_CAP


def test_schedule_kwarg_validates_eagerly():
    def kfn(t, x):
        t.store(t.load(x) + 1)

    with pytest.raises(ValueError, match="schedule"):
        nmc.jit(kfn, schedule="bogus")
    ck = nmc.jit(kfn, tiles=2, runtime=_RT)
    with pytest.raises(ValueError, match="schedule"):
        ck(_rand(16, 8), schedule="bogus")


def test_invalid_user_plan_is_rejected():
    def kfn(t, x):
        t.store(t.load(x) + 1)

    b = nmc.jit(kfn).trace(_rand(32, 8))
    bad = S.SchedulePlan("axis", (16, 16), ("caesar",), (0,), 2, 8,
                         0.0, 0.0, 0.0, "user")
    with pytest.raises(P.PartitionError, match="expects 1 shards"):
        S.realize(b, bad)                 # chunk vector vs engines mismatch
    bad2 = S.SchedulePlan("axis", (16, 16), ("caesar", "vliw"), (0, 1),
                          2, 8, 0.0, 0.0, 0.0, "user")
    with pytest.raises(ValueError, match="unknown engine"):
        S.realize(b, bad2)
    bad3 = S.SchedulePlan("axis", (16, 16), ("caesar", "caesar"), (0,),
                          2, 8, 0.0, 0.0, 0.0, "user")
    with pytest.raises(ValueError, match="length mismatch"):
        S.realize(b, bad3)


# ---------------------------------------------------------------------------
# Uniform-mode regression: cost-picked remainder spread / tail placement
# ---------------------------------------------------------------------------

def test_uniform_mode_beats_seed_on_uneven_matmul():
    """The seed planner ceil-packs chunks (9 words over 8 tiles -> 5 busy
    shards, tail last); uniform mode keeps uniform chunkings but lets the
    wave model arbitrate the remainder spread — on an uneven sew32 matmul
    the balanced spread engages every tile and models strictly fewer
    cycles, while staying bit-exact."""
    sew, cols, tiles = 32, 36, 8
    A = _rand((8, 8), sew)
    B = _rand((8, cols), sew)

    def mm(t, A, B):
        a = t.consts(A)
        rows = [t.load(B[r]) for r in range(8)]
        for i in range(8):
            acc = None
            for kk in range(8):
                acc = nmc.mac(acc, a[i, kk], rows[kk])
            t.store(acc)

    ck = nmc.jit(mm, sew=sew, tiles=tiles, partition="axis", runtime=_RT)
    b = ck.trace(A, B)
    uni = S.uniform_plan(b, tiles, partition="axis")
    assert uni.modeled_cycles < uni.seed_cycles      # the regression fixed
    # the cost model spread the remainder across all 8 tiles instead of
    # ceil-packing 9 words onto 5 shards
    assert uni.n_shards == tiles
    assert np.array_equal(ck(A, B), ck.oracle(A, B))


def test_uniform_mode_keeps_seed_chunking_when_it_wins():
    """Uniform mode is tie-broken to the seed planner's exact behavior:
    when the ceil-packed chunking is not beaten, the plan reproduces the
    seed's shard layout (no gratuitous churn)."""
    def kfn(t, x):
        t.store((t.load(x) * 3 + 1).max(0))

    n, tiles = 256, 4                      # divides evenly: no remainder
    b = nmc.jit(kfn).trace(_rand(n, 8))
    uni = S.uniform_plan(b, tiles)
    seed_pl = P.plan(b, tiles)
    assert uni.chunks == tuple(p[0][2] - p[0][1] for p in seed_pl.pieces)
    assert uni.order == tuple(range(uni.n_shards))


# ---------------------------------------------------------------------------
# Autotuning
# ---------------------------------------------------------------------------

def test_autotuned_never_models_more_than_uniform():
    def kfn(t, x, y):
        t.store((t.load(x, bank=0) * 3 + t.load(y)).max(0))

    for tiles in (2, 4, 8):
        b = nmc.jit(kfn).trace(_rand(300, 8), _rand(300, 8))
        tuned = S.autotune(b, tiles)
        assert tuned.modeled_cycles <= tuned.uniform_cycles
        assert tuned.uniform_cycles <= tuned.seed_cycles


def test_autotuned_matmul_bit_exact_sync_and_async():
    sew, cols, tiles = 8, 512, 8
    A, B = _rand((8, 8), sew), _rand((8, cols), sew)

    def mm(t, A, B):
        a = t.consts(A)
        rows = [t.load(B[r]) for r in range(8)]
        for i in range(8):
            acc = None
            for kk in range(8):
                acc = nmc.mac(acc, a[i, kk], rows[kk])
            t.store(acc)

    ck = nmc.jit(mm, sew=sew, tiles=tiles, runtime=_RT)
    ref = ck(A, B, schedule="uniform")
    assert np.array_equal(ref, ck.oracle(A, B))
    out = ck(A, B, schedule="auto")
    assert np.array_equal(ref, out)
    fut = ck.call_async(A, B, schedule="auto")
    assert np.array_equal(ref, fut.result())
    tuned = ck.plan_schedule(A, B, schedule="auto")
    assert tuned.modeled_cycles < tuned.uniform_cycles


# ---------------------------------------------------------------------------
# Mixed-engine waves
# ---------------------------------------------------------------------------

def test_qrelu_dispatches_mixed_engine_wave_in_one_launch():
    """The heterogeneous qrelu tape (7 bus-expressible rows + 1 unsigned
    minu row) autotunes to a genuinely mixed Caesar+Carus wave — one
    launch wave, one resident-pool dispatch call, per-engine compile
    buckets — and stays bit-exact vs the all-Carus uniform plan."""
    S.clear_plan_cache()
    kfn, args = programs.qrelu_case(8)
    rt = nmc.NmcRuntime()
    ck = nmc.jit(kfn, tiles=8, partition="rows", runtime=rt)

    uni = ck.plan_schedule(*args, schedule="uniform")
    assert set(uni.engines) == {"carus"}     # whole-tape fallback engine
    tuned = ck.plan_schedule(*args, schedule="auto")
    assert tuned.mixed                        # genuinely heterogeneous
    assert set(tuned.engines) == {"caesar", "carus"}
    assert tuned.modeled_cycles < uni.modeled_cycles

    ref = ck(*args, schedule="uniform")
    q = rt.queue
    w0, m0 = q.waves, q.mixed_engine_waves
    d0 = rt.resident.dispatch_calls
    out = ck(*args, schedule="auto")
    assert np.array_equal(ref, out)
    assert np.array_equal(ref, ck.oracle(*args))
    assert q.waves - w0 == 1                       # one launch wave...
    assert q.mixed_engine_waves - m0 == 1          # ...mixing both engines
    assert rt.resident.dispatch_calls - d0 == 1    # one parallel step
    # async path takes the identical (cached) plan
    fut = ck.call_async(*args, schedule="auto")
    assert np.array_equal(ref, fut.result())
    assert q.mixed_engine_waves - m0 == 2


def test_mixed_wave_verifies_per_engine_buckets():
    """verify_wave groups the bucket-agreement contract per engine: a
    mixed wave's Caesar and Carus shards legitimately sit at different
    instruction counts."""
    kfn, args = programs.qrelu_case(8)
    ck = nmc.jit(kfn, tiles=8, partition="rows", runtime=_RT,
                 schedule="auto")
    pplan, lks = ck.lower_wave(*args)
    engines = {lk.engine for lk in lks}
    assert engines == {"caesar", "carus"}
    rep = nmc.verify_wave(pplan.parent, pplan, lks)
    assert not rep.errors, rep.render()
    by_eng = {}
    for lk in lks:
        by_eng.setdefault(lk.engine, set()).add(lk.program.n_instr)
    assert all(len(v) == 1 for v in by_eng.values())
