"""Unified NMC program IR + batched tile-pool executor (DESIGN.md §5).

Covers the refactor's three contracts:
* IR encode/decode round-trips losslessly for both engine formats,
* the vmapped multi-tile pool is bit-exact vs. the single-instance path for
  every kernel in programs.ALL_KERNELS x SEW in {8, 16, 32}, and
* the pool compiles once per (engine, sew, n_instr) program shape.
"""

import numpy as np
import pytest

from repro.core import ecpu, isa, programs
from repro.core import timing
from repro.core.isa import CaesarOp, VOp
from repro.nmc import Program, TilePool, caesar_entry, carus_entry
from repro.nmc.program import PROG_DTYPE

RNG = np.random.default_rng(7)

# reduced sizes keep the scanned engines fast in CI (mirrors test_engines)
SMALL = {"caesar_bytes": 2048, "carus_bytes": 4096}


def _build(name, sew):
    kw = SMALL if name in ("xor", "add", "mul", "relu", "leaky_relu",
                           "maxpool") else {}
    return programs.build(name, sew, **kw)


# ---------------------------------------------------------------------------
# IR round-trips
# ---------------------------------------------------------------------------

def test_caesar_stream_roundtrip():
    ops = [o for o in CaesarOp if o != CaesarOp.CSRW]
    stream = [(ops[int(RNG.integers(len(ops)))], int(RNG.integers(8192)),
               int(RNG.integers(8192)), int(RNG.integers(8192)))
              for _ in range(64)]
    prog = Program.from_caesar_stream(stream, sew=16)
    assert prog.shape_key == ("caesar", 16, 64)
    assert prog.to_caesar_stream() == stream


def test_carus_trace_roundtrip():
    from repro.core.carus import trace_entry as legacy_entry
    vops = list(isa.VOP_COMPACT)
    trace = [legacy_entry(vops[int(RNG.integers(len(vops)))],
                          vd=int(RNG.integers(32)), vs1=int(RNG.integers(32)),
                          vs2=int(RNG.integers(32)),
                          sval1=int(RNG.integers(-2**31, 2**31)),
                          sval2=int(RNG.integers(-2**31, 2**31)),
                          imm=int(RNG.integers(-16, 16)),
                          mode=int(RNG.integers(16)))
             for _ in range(64)]
    prog = Program.from_carus_trace(trace, sew=8)
    assert prog.shape_key == ("carus", 8, 64)
    for back, orig in zip(prog.to_carus_trace(), trace):
        for f in isa.CARUS_TRACE_DTYPE.names:
            assert back[f] == orig[f], f


def test_ir_entry_helpers_match_legacy_formats():
    e = caesar_entry(CaesarOp.MAC_STORE, 7, 100, 4196)
    assert e.dtype == PROG_DTYPE
    assert (int(e["op"]), int(e["dest"]), int(e["src1"]), int(e["src2"])) \
        == (int(CaesarOp.MAC_STORE), 7, 100, 4196)
    v = carus_entry(VOp.VMACC, vd=3, vs1=1, vs2=2, sval1=-5,
                    mode=isa.MODE_VX)
    assert int(v["op"]) == isa.COMPACT_ID[VOp.VMACC]
    assert (int(v["dest"]), int(v["src1"]), int(v["src2"]),
            int(v["sval1"]), int(v["mode"])) == (3, 1, 2, -5, isa.MODE_VX)


def test_builder_emits_ir_and_legacy_timing_agrees():
    """Builders emit PROG_DTYPE entries; the unified cost path must agree
    with a Program reconstructed from the decoded legacy stream."""
    kb = _build("gemm", 16)
    assert kb.caesar.program.entries.dtype == PROG_DTYPE
    assert kb.carus.program.entries.dtype == PROG_DTYPE
    legacy = Program.from_caesar_stream(kb.caesar.program.to_caesar_stream(),
                                        16)
    a = timing.program_cycles(kb.caesar.program, kb.caesar.host_cycles)
    b = timing.program_cycles(legacy, kb.caesar.host_cycles)
    assert a == b
    legacy_k = Program.from_carus_trace(kb.carus.program.to_carus_trace(), 16)
    ak = timing.program_cycles(kb.carus.program.with_sew(16))
    bk = timing.program_cycles(legacy_k)
    assert ak == bk
    assert timing.program_vrf_accesses(kb.carus.program.with_sew(16)) \
        == timing.program_vrf_accesses(legacy_k)


def test_untagged_engine_build_costs_through_wrappers():
    """Hand-built EngineBuilds without engine/sew tags (as tests construct
    them) must cost identically whether their stream holds legacy tuples or
    raw IR entries — the wrappers carry the engine knowledge."""
    legacy = programs.EngineBuild([(CaesarOp.ADD, 10, 0, 4096)] * 4,
                                  np.zeros(8192, np.int32), (10, 1))
    ir = programs.EngineBuild([caesar_entry(CaesarOp.ADD, 10, 0, 4096)] * 4,
                              np.zeros(8192, np.int32), (10, 1))
    assert timing.caesar_cycles(legacy) == timing.caesar_cycles(ir)
    k_ir = programs.EngineBuild([programs.trace_entry(VOp.VSETVL, sval1=64)],
                                np.zeros((32, 256), np.int32), (0, 4))
    assert timing.carus_cycles(k_ir, 8).n_instrs == 1


def test_ecpu_issue_trace_is_ir_program():
    """The eCPU's issue trace round-trips through the IR and replays
    bit-exactly on the batched executor."""
    import jax.numpy as jnp
    from repro.core import alu, carus

    src = """
        li   t0, 1024
        vsetvli t1, t0, e8
        xvnmc.vadd.vv v20, v1, v2
        halt
    """
    vpu = carus.CarusVPU()
    a = RNG.integers(-128, 128, 1024).astype(np.int8)
    b = RNG.integers(-128, 128, 1024).astype(np.int8)
    vrf = np.zeros((32, 256), np.int32)
    vrf[1], vrf[2] = alu.pack_np(a), alu.pack_np(b)
    cpu = ecpu.ECpu(vpu, jnp.asarray(vrf))
    cpu.load_program(ecpu.assemble(src))
    cpu.run()
    prog = cpu.program()
    assert isinstance(prog, Program) and prog.engine == "carus"
    assert prog.n_instr == cpu.vector_retired == 2
    # replay the full trace through the pool; must equal the eager result
    pool = TilePool()
    (final,) = pool.run([prog], [vrf])
    assert (np.asarray(cpu.vrf) == final).all()


# ---------------------------------------------------------------------------
# Batched multi-tile execution: bit-exact vs the single-instance path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sew", [8, 16, 32])
def test_pool_bit_exact_all_kernels(sew):
    kbs = [_build(name, sew) for name in programs.ALL_KERNELS]
    pool = TilePool()
    builds = [kb.caesar for kb in kbs] + [kb.carus for kb in kbs]
    batched = pool.run_builds(builds)
    for eb, got in zip(builds, batched):
        # full output identical to the single-instance path, not just the
        # oracle-covered prefix
        single = programs.run_build(eb)
        assert (np.asarray(single) == np.asarray(got)).all(), \
            (eb.engine, sew)
        exp = np.asarray(eb.oracle).reshape(-1)
        assert (np.asarray(got).reshape(-1)[:exp.size] == exp).all(), \
            (eb.engine, sew)
    # grouped dispatch: strictly fewer compiles than kernel instances
    assert pool.compiles == len({eb.program.shape_key for eb in builds})
    assert pool.compiles < len(builds)


def test_pool_compiles_once_per_shape():
    """Same-shape instances share one compile; re-dispatch hits the cache."""
    kbs = [_build(name, 8) for name in ("xor", "add", "mul")]
    builds = [kb.caesar for kb in kbs]
    keys = {eb.program.shape_key for eb in builds}
    assert len(keys) == 1, keys       # one shape => batched as 3 tiles
    pool = TilePool()
    pool.run_builds(builds)
    assert pool.compiles == 1
    assert pool.dispatches == 1 and pool.programs_run == 3
    pool.run_builds(builds)           # same shape again: no new compile
    assert pool.compiles == 1
    assert pool.shape_keys_compiled == keys


def test_pool_groups_heterogeneous_batches():
    kbs = [_build("xor", 8), _build("relu", 8), _build("matmul", 8)]
    pool = TilePool()
    res = programs.verify_sweep(kbs, pool)
    assert all(all(v.values()) for v in res.values())
    shapes = {getattr(kb, e).program.shape_key
              for kb in kbs for e in ("caesar", "carus")}
    assert pool.compiles == len(shapes)
    # xor and relu lower to the same caesar shape => batched together
    assert pool.programs_run == 6 and pool.dispatches == len(shapes)
