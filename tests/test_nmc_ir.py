"""Unified NMC program IR + batched tile-pool executors (DESIGN.md §5).

Covers the IR and scheduler contracts:
* IR encode/decode round-trips losslessly for both engine formats,
* the vmapped multi-tile pool is bit-exact vs. the single-instance path for
  every kernel in programs.ALL_KERNELS x SEW in {8, 16, 32},
* the exact-shape pool compiles once per (engine, sew, n_instr) shape,
* NOP padding is bit-exact and zero-cost on both engines (the bucketed
  scheduler's filler),
* the bucketed pool compiles once per (engine, sew, instr-bucket,
  tile-bucket) over a full Table V sweep — O(#buckets), not O(#shapes), and
* the resident pool keeps tile state on device across dispatches with
  explicit load/store byte accounting.
"""

import numpy as np
import pytest

from repro.core import ecpu, energy, isa, programs
from repro.core import timing
from repro.core.isa import CaesarOp, VOp
from repro.nmc import (BucketedPool, Program, ResidentPool, TilePool,
                       caesar_entry, carus_entry, instr_bucket, nop_entry,
                       tile_bucket)
from repro.nmc.engine import get_engine
from repro.nmc.program import PROG_DTYPE

RNG = np.random.default_rng(7)

# reduced sizes keep the scanned engines fast in CI (mirrors test_engines)
SMALL = {"caesar_bytes": 2048, "carus_bytes": 4096}


def _build(name, sew):
    kw = SMALL if name in ("xor", "add", "mul", "relu", "leaky_relu",
                           "maxpool") else {}
    return programs.build(name, sew, **kw)


# ---------------------------------------------------------------------------
# IR round-trips
# ---------------------------------------------------------------------------

def test_caesar_stream_roundtrip():
    ops = [o for o in CaesarOp if o != CaesarOp.CSRW]
    stream = [(ops[int(RNG.integers(len(ops)))], int(RNG.integers(8192)),
               int(RNG.integers(8192)), int(RNG.integers(8192)))
              for _ in range(64)]
    prog = Program.from_caesar_stream(stream, sew=16)
    assert prog.shape_key == ("caesar", 16, 64)
    assert prog.to_caesar_stream() == stream


def test_carus_trace_roundtrip():
    from repro.core.carus import trace_entry as legacy_entry
    vops = list(isa.VOP_COMPACT)
    trace = [legacy_entry(vops[int(RNG.integers(len(vops)))],
                          vd=int(RNG.integers(32)), vs1=int(RNG.integers(32)),
                          vs2=int(RNG.integers(32)),
                          sval1=int(RNG.integers(-2**31, 2**31)),
                          sval2=int(RNG.integers(-2**31, 2**31)),
                          imm=int(RNG.integers(-16, 16)),
                          mode=int(RNG.integers(16)))
             for _ in range(64)]
    prog = Program.from_carus_trace(trace, sew=8)
    assert prog.shape_key == ("carus", 8, 64)
    for back, orig in zip(prog.to_carus_trace(), trace):
        for f in isa.CARUS_TRACE_DTYPE.names:
            assert back[f] == orig[f], f


def test_ir_entry_helpers_match_legacy_formats():
    e = caesar_entry(CaesarOp.MAC_STORE, 7, 100, 4196)
    assert e.dtype == PROG_DTYPE
    assert (int(e["op"]), int(e["dest"]), int(e["src1"]), int(e["src2"])) \
        == (int(CaesarOp.MAC_STORE), 7, 100, 4196)
    v = carus_entry(VOp.VMACC, vd=3, vs1=1, vs2=2, sval1=-5,
                    mode=isa.MODE_VX)
    assert int(v["op"]) == isa.COMPACT_ID[VOp.VMACC]
    assert (int(v["dest"]), int(v["src1"]), int(v["src2"]),
            int(v["sval1"]), int(v["mode"])) == (3, 1, 2, -5, isa.MODE_VX)


def test_builder_emits_ir_and_legacy_timing_agrees():
    """Builders emit PROG_DTYPE entries; the unified cost path must agree
    with a Program reconstructed from the decoded legacy stream."""
    kb = _build("gemm", 16)
    assert kb.caesar.program.entries.dtype == PROG_DTYPE
    assert kb.carus.program.entries.dtype == PROG_DTYPE
    legacy = Program.from_caesar_stream(kb.caesar.program.to_caesar_stream(),
                                        16)
    a = timing.program_cycles(kb.caesar.program, kb.caesar.host_cycles)
    b = timing.program_cycles(legacy, kb.caesar.host_cycles)
    assert a == b
    legacy_k = Program.from_carus_trace(kb.carus.program.to_carus_trace(), 16)
    ak = timing.program_cycles(kb.carus.program.with_sew(16))
    bk = timing.program_cycles(legacy_k)
    assert ak == bk
    assert timing.program_vrf_accesses(kb.carus.program.with_sew(16)) \
        == timing.program_vrf_accesses(legacy_k)


def test_untagged_engine_build_costs_through_wrappers():
    """Hand-built EngineBuilds without engine/sew tags (as tests construct
    them) must cost identically whether their stream holds legacy tuples or
    raw IR entries — the wrappers carry the engine knowledge."""
    legacy = programs.EngineBuild([(CaesarOp.ADD, 10, 0, 4096)] * 4,
                                  np.zeros(8192, np.int32), (10, 1))
    ir = programs.EngineBuild([caesar_entry(CaesarOp.ADD, 10, 0, 4096)] * 4,
                              np.zeros(8192, np.int32), (10, 1))
    assert timing.caesar_cycles(legacy) == timing.caesar_cycles(ir)
    k_ir = programs.EngineBuild([programs.trace_entry(VOp.VSETVL, sval1=64)],
                                np.zeros((32, 256), np.int32), (0, 4))
    assert timing.carus_cycles(k_ir, 8).n_instrs == 1


def test_ecpu_issue_trace_is_ir_program():
    """The eCPU's issue trace round-trips through the IR and replays
    bit-exactly on the batched executor."""
    import jax.numpy as jnp
    from repro.core import alu, carus

    src = """
        li   t0, 1024
        vsetvli t1, t0, e8
        xvnmc.vadd.vv v20, v1, v2
        halt
    """
    vpu = carus.CarusVPU()
    a = RNG.integers(-128, 128, 1024).astype(np.int8)
    b = RNG.integers(-128, 128, 1024).astype(np.int8)
    vrf = np.zeros((32, 256), np.int32)
    vrf[1], vrf[2] = alu.pack_np(a), alu.pack_np(b)
    cpu = ecpu.ECpu(vpu, jnp.asarray(vrf))
    cpu.load_program(ecpu.assemble(src))
    cpu.run()
    prog = cpu.program()
    assert isinstance(prog, Program) and prog.engine == "carus"
    assert prog.n_instr == cpu.vector_retired == 2
    # replay the full trace through the pool; must equal the eager result
    pool = TilePool()
    (final,) = pool.run([prog], [vrf])
    assert (np.asarray(cpu.vrf) == final).all()


# ---------------------------------------------------------------------------
# Batched multi-tile execution: bit-exact vs the single-instance path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sew", [8, 16, 32])
def test_pool_bit_exact_all_kernels(sew):
    kbs = [_build(name, sew) for name in programs.ALL_KERNELS]
    pool = TilePool()
    builds = [kb.caesar for kb in kbs] + [kb.carus for kb in kbs]
    batched = pool.run_builds(builds)
    for eb, got in zip(builds, batched):
        # full output identical to the single-instance path, not just the
        # oracle-covered prefix
        single = programs.run_build(eb)
        assert (np.asarray(single) == np.asarray(got)).all(), \
            (eb.engine, sew)
        exp = np.asarray(eb.oracle).reshape(-1)
        assert (np.asarray(got).reshape(-1)[:exp.size] == exp).all(), \
            (eb.engine, sew)
    # grouped dispatch: strictly fewer compiles than kernel instances
    assert pool.compiles == len({eb.program.shape_key for eb in builds})
    assert pool.compiles < len(builds)


def test_pool_compiles_once_per_shape():
    """Same-shape instances share one compile; re-dispatch hits the cache."""
    kbs = [_build(name, 8) for name in ("xor", "add", "mul")]
    builds = [kb.caesar for kb in kbs]
    keys = {eb.program.shape_key for eb in builds}
    assert len(keys) == 1, keys       # one shape => batched as 3 tiles
    pool = TilePool()
    pool.run_builds(builds)
    assert pool.compiles == 1
    assert pool.dispatches == 1 and pool.programs_run == 3
    pool.run_builds(builds)           # same shape again: no new compile
    assert pool.compiles == 1
    assert pool.shape_keys_compiled == keys


def test_pool_groups_heterogeneous_batches():
    kbs = [_build("xor", 8), _build("relu", 8), _build("matmul", 8)]
    pool = TilePool()
    res = programs.verify_sweep(kbs, pool)
    assert all(all(v.values()) for v in res.values())
    shapes = {getattr(kb, e).program.shape_key
              for kb in kbs for e in ("caesar", "carus")}
    assert pool.compiles == len(shapes)
    # xor and relu lower to the same caesar shape => batched together
    assert pool.programs_run == 6 and pool.dispatches == len(shapes)


# ---------------------------------------------------------------------------
# NOP padding: bit-exact no-op semantics, zero cycle/energy cost
# ---------------------------------------------------------------------------

def test_instr_bucket_rule():
    assert [instr_bucket(n) for n in (0, 1, 2, 3, 4, 5, 129, 512, 513)] \
        == [1, 1, 2, 4, 4, 8, 256, 512, 1024]
    assert [tile_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] \
        == [1, 2, 4, 4, 8, 8, 16]


@pytest.mark.parametrize("engine", ["caesar", "carus"])
@pytest.mark.parametrize("kernel", ["leaky_relu", "maxpool"])
def test_nop_padding_bit_exact_and_zero_cost(engine, kernel):
    """Padded program ≡ unpadded: identical final state on the scan engine,
    identical cycles, VRF accesses and energy (NOPs are free)."""
    eb = getattr(_build(kernel, 8), engine)
    prog = eb.program
    padded = prog.pad_to(instr_bucket(prog.n_instr + 1))
    assert padded.n_instr > prog.n_instr
    assert padded.n_nops == padded.n_instr - prog.n_instr
    assert padded.bucket_key[2] >= prog.bucket_key[2]
    eng = get_engine(engine)
    s1 = eng.run(eng.init_state(eb.mem), prog)
    s2 = eng.run(eng.init_state(eb.mem), padded)
    assert (np.asarray(s1) == np.asarray(s2)).all()
    assert timing.program_cycles(prog, eb.host_cycles) \
        == timing.program_cycles(padded, eb.host_cycles)
    assert energy.program_energy(prog, eb.host_cycles) \
        == energy.program_energy(padded, eb.host_cycles)
    if engine == "carus":
        assert timing.program_vrf_accesses(prog) \
            == timing.program_vrf_accesses(padded)


def test_nop_entry_roundtrips_through_legacy_formats():
    c = Program.from_entries("caesar", 8, [nop_entry("caesar")] * 3)
    assert c.n_nops == 3
    assert c.to_caesar_stream() == [(CaesarOp.NOP, 0, 0, 0)] * 3
    k = Program.from_entries("carus", 8, [nop_entry("carus")] * 2)
    assert k.n_nops == 2 and k.vops() == [VOp.VNOP, VOp.VNOP]
    back = Program.from_carus_trace(k.to_carus_trace(), 8)
    assert back.n_nops == 2


# ---------------------------------------------------------------------------
# Bucketed scheduler: one compile per (engine, sew, instr-bucket, tile-bucket)
# ---------------------------------------------------------------------------

def _caesar_prog(n_instr: int, sew: int = 8) -> Program:
    return Program.from_entries(
        "caesar", sew,
        [caesar_entry(CaesarOp.ADD, 100 + i, i, 4096 + i)
         for i in range(n_instr)])


def test_bucketed_pool_merges_ragged_shapes():
    """Four distinct exact shapes in one instr bucket: one compile, one
    batched dispatch, bit-exact vs the exact-shape pool."""
    progs = [_caesar_prog(n) for n in (5, 6, 7, 8)]
    states = [np.arange(8192, dtype=np.int32) for _ in progs]
    pool = BucketedPool()
    outs = pool.run(progs, [s.copy() for s in states])
    assert len({p.shape_key for p in progs}) == 4       # exact: 4 traces
    assert pool.compiles == 1                           # bucketed: 1
    assert pool.dispatches == 1 and pool.programs_run == 4
    # pad_waste: 4 tiles x bucket 8 - (5+6+7+8) real instructions
    assert pool.pad_waste == 4 * 8 - (5 + 6 + 7 + 8)
    assert pool.bytes_moved > 0
    exact = TilePool()
    refs = exact.run(progs, [s.copy() for s in states])
    assert exact.compiles == 4
    for got, ref in zip(outs, refs):
        assert (got == ref).all()


def test_bucketed_pool_tile_count_buckets_reuse_traces():
    """Partial batches pad to power-of-two tile counts and reuse the
    padded-batch trace instead of re-tracing per count."""
    pool = BucketedPool()
    state = np.zeros(8192, np.int32)
    pool.run([_caesar_prog(8)] * 3, [state] * 3)   # 3 tiles -> bucket 4
    assert pool.compiles == 1
    pool.run([_caesar_prog(8)] * 4, [state] * 4)   # 4 tiles -> same bucket
    assert pool.compiles == 1
    pool.run([_caesar_prog(6)] * 4, [state] * 4)   # same buckets again
    assert pool.compiles == 1
    pool.run([_caesar_prog(8)] * 2, [state] * 2)   # 2 tiles -> new bucket
    assert pool.compiles == 2


@pytest.mark.parametrize("sew", [8, 16, 32])
def test_bucketed_pool_table_v_sweep(sew):
    """Acceptance (ISSUE 2): the full Table V kernel sweep through the
    bucketed pool is bit-exact vs the single-program path and compiles at
    most once per (engine, sew, bucket) — asserted on the pool counters."""
    kbs = [_build(name, sew) for name in programs.ALL_KERNELS]
    builds = [getattr(kb, e) for kb in kbs for e in ("caesar", "carus")]
    pool = BucketedPool()
    outs = pool.run_builds(builds)
    for eb, got in zip(builds, outs):
        # bit-exact vs the per-engine oracles (the single-program path is
        # checked against the same oracles in test_pool_bit_exact_all_kernels
        # and against padded programs in the NOP tests above)
        exp = np.asarray(eb.oracle).reshape(-1)
        assert (np.asarray(got).reshape(-1)[:exp.size] == exp).all(), \
            (eb.engine, sew)
    buckets = {eb.program.bucket_key for eb in builds}
    shapes = {eb.program.shape_key for eb in builds}
    # one grouped run: exactly one compile per occupied bucket, and
    # bucketing must not exceed the exact-shape compile count
    assert pool.compiles == len(buckets)
    assert pool.compiles <= len(shapes)
    assert pool.programs_run == len(builds)
    # spot-check full bit-exactness vs the single-program path
    for i in (0, 1):
        single = programs.run_build(builds[i])
        assert (np.asarray(single) == np.asarray(outs[i])).all()


# ---------------------------------------------------------------------------
# Resident tile array: memory-mode/compute-mode duality
# ---------------------------------------------------------------------------

def test_resident_pool_state_persists_across_dispatches():
    """Two compute-mode dispatches against one resident tile must equal the
    concatenated program run in one shot — and share one trace."""
    mem = np.zeros(8192, np.int32)
    mem[0], mem[4096] = 5, 7
    pa = Program.from_entries(
        "caesar", 32, [caesar_entry(CaesarOp.ADD, 100, 0, 4096)])
    pb = Program.from_entries(
        "caesar", 32, [caesar_entry(CaesarOp.XOR, 101, 100, 4096)])
    rp = ResidentPool()
    rp.load("t", "caesar", mem)
    rp.dispatch([("t", pa)])
    rp.dispatch([("t", pb)])
    eng = get_engine("caesar")
    both = Program.from_entries("caesar", 32,
                                list(pa.entries) + list(pb.entries))
    ref = np.asarray(eng.run(eng.init_state(mem), both))
    assert (np.asarray(rp.state("t")) == ref).all()
    assert rp.compiles == 1            # same (caesar, 32, 1, 1) bucket twice
    assert rp.dispatches == 2 and rp.loads == 1


def test_resident_pool_byte_accounting_and_outputs():
    """load moves the full image, dispatch only instruction bytes, store
    only the result words — and outputs stay bit-exact vs the oracle."""
    kb = _build("xor", 8)
    eb = kb.caesar
    rp = ResidentPool()
    rp.load("t0", "caesar", eb.mem)
    state_bytes = int(rp.state("t0").size) * 4
    assert rp.bytes_moved == state_bytes
    prog = eb.program
    rp.dispatch([("t0", prog)])
    instr_bytes = rp.bytes_moved - state_bytes
    assert instr_bytes == instr_bucket(prog.n_instr) * PROG_DTYPE.itemsize
    assert instr_bytes < state_bytes   # the residency win
    before_store = rp.bytes_moved
    out = rp.store("t0", eb.out_slice, kb.sew)
    assert rp.bytes_moved - before_store == eb.out_slice[1] * 4
    exp = np.asarray(eb.oracle).reshape(-1)
    assert (out.reshape(-1)[:exp.size] == exp).all()


def test_resident_run_builds_matches_pool_run_builds():
    kbs = [_build(n, 8) for n in ("xor", "add", "relu")]
    builds = [getattr(kb, e) for kb in kbs for e in ("caesar", "carus")]
    rp = ResidentPool()
    got = rp.run_builds(builds)
    ref = BucketedPool().run_builds(builds)
    for a, b in zip(got, ref):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert rp.loads == len(builds) and rp.stores == len(builds)
    # tile memories are still resident (memory mode) after the run
    assert len(rp.tiles) == len(builds)
