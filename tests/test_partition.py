"""Tile-parallel partitioning planner (DESIGN.md §9).

Two layers of coverage:

* **Planner properties** (hypothesis, or the deterministic vendored shim
  offline; no JAX, pure tape/oracle level): random lengths × split
  factors round-trip — shard oracles gather back to the unsharded
  oracle bit-exactly, ragged tails land on the last shard, slide halos
  reproduce conv's column overlap, row splits reassemble store blocks.
* **Executed waves** (the engines, via one shared runtime/jit cache):
  partitioned sync and async calls are bit-exact vs the single-tile
  path on both engines, shard programs pre-pad into one instruction
  bucket per wave, and repeated partitioned calls hit the compile cache.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import nmc
from repro.core import alu
from repro.nmc import partition as P

SEWS = (8, 16, 32)
RNG = np.random.default_rng(7)

# one shared runtime for the module: every executed wave shares a jit cache
_RT = nmc.NmcRuntime()


def _rand(shape, sew, rng=RNG):
    info = np.iinfo(alu.NP_DTYPES[sew])
    return rng.integers(info.min, info.max + 1, shape,
                        dtype=alu.NP_DTYPES[sew])


def _trace(kfn, args, sew):
    return nmc.jit(kfn, sew=sew).trace(*args)


# ---------------------------------------------------------------------------
# Planner properties (tape/oracle level — no engine execution)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 600), st.integers(1, 9), st.sampled_from(SEWS),
       st.integers(0, 2 ** 31))
def test_axis_split_round_trips_random_lengths(n, tiles, sew, seed):
    """Random lengths x split factors: the gathered shard oracles equal
    the unsharded oracle bit-exactly, every shard but the last covers a
    whole number of words, and the ragged tail lands on the last tile."""
    rng = np.random.default_rng(seed)
    x, y = _rand(n, sew, rng), _rand(n, sew, rng)

    def kfn(t, x, y):
        t.store((t.load(x, bank=0) * 3 + t.load(y)).max(0))

    b = _trace(kfn, (x, y), sew)
    pl = P.plan(b, tiles)
    assert 1 <= pl.n_shards <= tiles
    assert (pl.oracle() == b.oracle()).all()
    lanes = 32 // sew
    sizes = [hi - lo for (_, lo, hi) in
             (pc for shard in pl.pieces for pc in shard)]
    assert sum(sizes) == n
    if pl.n_shards > 1:
        head = set(sizes[:-1])
        assert len(head) == 1                  # equal word-aligned chunks
        assert next(iter(head)) % lanes == 0
        assert sizes[-1] <= next(iter(head))   # ragged tail on last tile


@settings(max_examples=25, deadline=None)
@given(st.integers(8, 200), st.integers(2, 8), st.integers(1, 5),
       st.integers(0, 2 ** 31))
def test_axis_split_slide_halo_round_trips(n, tiles, amount, seed):
    """Slides read ahead across chunk boundaries: the halo must hand each
    shard its true neighbours, zero-filling only at the real tail."""
    rng = np.random.default_rng(seed)
    x = _rand(n, 8, rng)

    def kfn(t, x):
        v = t.load(x)
        t.store(nmc.mac(v.slide_down(amount), 2, v))

    b = _trace(kfn, (x,), 8)
    pl = P.plan(b, tiles)
    assert pl.strategy == "axis"               # slides route to axis
    assert (pl.oracle() == b.oracle()).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(1, 8), st.integers(8, 64),
       st.integers(0, 2 ** 31))
def test_rows_split_round_trips_random_store_counts(m, tiles, p, seed):
    """Store-level (matmul-row) splits: shard oracles reassemble into the
    unsharded stacked output for any store count x split factor."""
    rng = np.random.default_rng(seed)
    A, B = _rand((m, 4), 8, rng), _rand((4, p), 8, rng)

    def kfn(t, A, B):
        a = t.consts(A)
        rows = [t.load(B[r]) for r in range(4)]
        for i in range(m):
            acc = None
            for kk in range(4):
                acc = nmc.mac(acc, a[i, kk], rows[kk])
            t.store(acc)

    b = _trace(kfn, (A, B), 8)
    pl = P.plan(b, tiles, partition="rows")
    assert pl.n_shards == min(tiles, m)
    assert (pl.oracle() == b.oracle()).all()
    # balanced contiguous blocks: shard sizes differ by at most one store
    counts = [len(pc) for pc in pl.pieces]
    assert max(counts) - min(counts) <= 1


def test_auto_strategy_rules():
    """auto: rows when stores distribute evenly and there are no slides;
    slides (conv's shifted replicas) and single stores route to axis."""
    x = _rand(64, 8)
    A, B = _rand((8, 4), 8), _rand((4, 64), 8)

    def mm(t, A, B):
        a = t.consts(A)
        rows = [t.load(B[r]) for r in range(4)]
        for i in range(8):
            acc = None
            for kk in range(4):
                acc = nmc.mac(acc, a[i, kk], rows[kk])
            t.store(acc)

    def ew(t, x):
        t.store(t.load(x) + 1)

    def slid(t, x):
        v = t.load(x)
        t.store(nmc.mac(v.slide_down(1), 1, v))

    assert P.plan(_trace(mm, (A, B), 8), 4).strategy == "rows"
    assert P.plan(_trace(mm, (A, B), 8), 3).strategy == "axis"  # 8 % 3 != 0
    assert P.plan(_trace(ew, (x,), 8), 4).strategy == "axis"
    assert P.plan(_trace(slid, (x,), 8), 4).strategy == "axis"
    assert P.plan(_trace(ew, (x,), 8), 1).strategy == "single"


def test_partition_errors_are_informative():
    x, y = _rand(16, 8), _rand(32, 8)

    def two_axes(t, x, y):                 # dead load of a different length
        t.load(y)
        t.store(t.load(x) + 1)

    b = _trace(two_axes, (x, y), 8)
    with pytest.raises(P.PartitionError, match="element axis"):
        P.plan(b, 4, partition="axis")
    with pytest.raises(P.PartitionError, match="stores"):
        P.plan(b, 4, partition="rows")     # single store
    with pytest.raises(P.PartitionError, match="no applicable"):
        P.plan(b, 4)
    with pytest.raises(ValueError, match="tiles"):
        P.plan(b, 0)
    with pytest.raises(ValueError, match="partition"):
        P.plan(b, 2, partition="diagonal")


def test_conv_column_split_matches_unsharded_oracle():
    """The Table V conv shape: output columns split across tiles with an
    f-1 halo; every shard's oracle window matches the unsharded conv."""
    A, F = _rand((8, 96), 8), _rand((3, 3), 8)

    def conv(t, A, F):
        fw = t.consts(F)
        av = [t.load(A[r]) for r in range(8)]
        sh = {(dj, r): av[r].slide_down(dj)
              for dj in range(1, 3) for r in range(8)}
        for i in range(6):
            acc = None
            for di in range(3):
                for dj in range(3):
                    src = av[i + di] if dj == 0 else sh[(dj, i + di)]
                    acc = nmc.mac(acc, fw[di, dj], src)
            t.store(acc, n=94)

    b = _trace(conv, (A, F), 8)
    for tiles in (2, 4, 8):
        pl = P.plan(b, tiles)
        assert pl.strategy == "axis"
        assert (pl.oracle() == b.oracle()).all(), tiles


# ---------------------------------------------------------------------------
# Executed waves: engines + queue + gather, shared jit cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["caesar", "carus"])
def test_partitioned_execution_bit_exact_vs_single_tile(engine):
    x, y = _rand(96, 8), _rand(96, 8)

    @nmc.jit(runtime=_RT)
    def k(t, x, y):
        t.store((t.load(x, bank=0) ^ t.load(y)).max(1))

    base = np.asarray(k(x, y, engine=engine))
    assert (base == k.oracle(x, y)).all()
    for tiles in (2, 4):
        sync = np.asarray(k(x, y, engine=engine, tiles=tiles))
        fut = k.call_async(x, y, engine=engine, tiles=tiles)
        assert isinstance(fut, nmc.GatherFuture)
        assert len(fut.futures) == tiles
        asyn = np.asarray(fut.result())
        assert (sync == base).all() and (asyn == base).all(), tiles
        assert fut.resolved and fut.done


@pytest.mark.parametrize("engine", ["caesar", "carus"])
def test_partitioned_matmul_rows_bit_exact(engine):
    A, B = _rand((8, 4), 8), _rand((4, 48), 8)

    @nmc.jit(runtime=_RT, tiles=4)
    def mm(t, A, B):
        a = t.consts(A)
        rows = [t.load(B[r]) for r in range(4)]
        for i in range(8):
            acc = None
            for kk in range(4):
                acc = nmc.mac(acc, a[i, kk], rows[kk])
            t.store(acc)

    base = np.asarray(mm(A, B, engine=engine, tiles=1))
    got = np.asarray(mm(A, B, engine=engine))        # decorator tiles=4
    assert got.shape == base.shape == (8, 48)
    assert (got == base).all()
    exp = (A.astype(np.int64) @ B.astype(np.int64)).astype(np.int8)
    assert (base == exp).all()


def test_wave_shards_share_one_instruction_bucket_and_compile():
    """lower_wave pre-pads every shard to the wave's common bucket, so a
    partitioned wave is one bucketed group: one compile, and repeated
    calls add none."""
    x, y = _rand(120, 8), _rand(120, 8)

    @nmc.jit(runtime=_RT)
    def k(t, x, y):
        t.store(t.load(x, bank=0) + t.load(y))

    pplan, lks = k.lower_wave(x, y, engine="caesar", tiles=4)
    keys = {lk.program.bucket_key for lk in lks}
    assert len(keys) == 1 and pplan.n_shards == 4
    n0 = {lk.program.n_instr for lk in lks}
    assert len(n0) == 1                    # NOP-padded to one shape
    k(x, y, engine="caesar", tiles=4)      # warm the bucket
    before = _RT.bucketed.compiles
    k(x, y, engine="caesar", tiles=4)
    fut = k.call_async(x, y, engine="caesar", tiles=4)
    fut.result()
    assert _RT.bucketed.compiles == before  # cache hits only


def test_partitioned_calls_keep_resident_state_bounded():
    """Shard k of every partitioned call reuses tile ("jit", k): N calls
    at tiles=T must leave at most T resident tile buffers, not N*T."""
    rt = nmc.NmcRuntime()
    x = _rand(64, 8)

    @nmc.jit(runtime=rt)
    def k(t, x):
        t.store(t.load(x) + 1)

    for _ in range(3):
        k(x, tiles=2)
    assert len(rt.resident.tiles) == 2
    assert rt.jit_tiles(2) == (("jit", 0), ("jit", 1))
    assert rt.jit_tile == ("jit", 0)


def test_conv_partitioned_executes_on_caesar():
    """Column-split conv with slide replicas, executed: gathers back to
    the exact single-tile output (halo correctness on the real engine)."""
    A, F = _rand((4, 64), 8), _rand((3, 3), 8)

    @nmc.jit(runtime=_RT)
    def conv(t, A, F):
        fw = t.consts(F)
        av = [t.load(A[r]) for r in range(4)]
        sh = {(dj, r): av[r].slide_down(dj)
              for dj in range(1, 3) for r in range(4)}
        for i in range(2):
            acc = None
            for di in range(3):
                for dj in range(3):
                    src = av[i + di] if dj == 0 else sh[(dj, i + di)]
                    acc = nmc.mac(acc, fw[di, dj], src)
            t.store(acc, n=62)

    base = np.asarray(conv(A, F, engine="caesar"))
    got = np.asarray(conv(A, F, engine="caesar", tiles=4))
    assert (got == base).all()


def test_partition_plan_public_surface():
    assert nmc.plan_partition is P.plan
    for name in ("PartitionPlan", "PartitionError", "GatherFuture",
                 "plan_partition"):
        assert name in nmc.__all__
