"""Static verifier tests (DESIGN.md §11): each defect class on a
hand-corrupted golden program, the check= knob policy, partition-safety
tampering, the dispatch-time asserts, and the clean-program properties
(well-formed fuzzed programs and registry-lowered kernels verify ok).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isa
from repro.core.isa import CaesarOp, VOp
from repro.nmc import check, frontend
from repro.nmc.check import (CheckReport, Diagnostic, VerificationError,
                             assert_submittable, assert_wave, verify_lowered,
                             verify_plan, verify_program, verify_wave)
from repro.nmc.program import (PROG_DTYPE, Program, caesar_entry, carus_entry,
                               instr_bucket)

SEWS = (8, 16, 32)
CAESAR_WORDS = 8192
N_REGS = 32


def cprog(entries, sew=8):
    return Program.from_entries("caesar", sew, entries)


def kprog(entries, sew=8):
    return Program.from_entries("carus", sew, entries)


def rules(report, rule):
    return report.by_rule(rule)


# ---------------------------------------------------------------------------
# Golden kernels to corrupt: one real lowered build per engine
# ---------------------------------------------------------------------------

@frontend.kernel
def golden(t, x, y):
    t.store((t.load(x) * 3 + t.load(y)).max(0))


def lower_golden(engine, n=64):
    x = np.arange(n, dtype=np.int64) - n // 2
    y = np.arange(n, dtype=np.int64)[::-1].copy()
    return golden.lower(x, y, engine=engine, check="off")


# ---------------------------------------------------------------------------
# Structural pass: Caesar
# ---------------------------------------------------------------------------

def test_caesar_bad_opcode_flagged_at_instr():
    lk = lower_golden("caesar")
    lk.program.entries["op"][3] = 63
    rep = verify_lowered(lk)
    d = rules(rep, "bad-opcode")
    assert d and d[0].severity == "error"
    assert d[0].pass_name == "structural"
    assert d[0].instr == 3
    assert d[0].kernel == "golden"
    # provenance: the diagnostic carries the tracer op the entry lowered from
    assert d[0].op_index == lk.prov[3]


def test_caesar_oob_address_flagged():
    lk = lower_golden("caesar")
    lk.program.entries["src1"][2] = CAESAR_WORDS + 7
    rep = verify_lowered(lk)
    d = rules(rep, "oob-address")
    assert d and d[0].pass_name == "structural" and d[0].instr == 2
    assert not rep.ok


def test_caesar_nonzero_carus_field_flagged():
    lk = lower_golden("caesar")
    lk.program.entries["mode"][1] = 2
    rep = verify_lowered(lk)
    d = rules(rep, "nonzero-carus-field")
    assert d and d[0].pass_name == "structural" and d[0].instr == 1


def test_caesar_nop_not_neutral_flagged():
    lk = lower_golden("caesar")
    n = lk.program.n_instr
    lk.pad_to(instr_bucket(n + 1))
    lk.program.entries["src1"][n] = 5          # corrupt a padding NOP
    rep = verify_lowered(lk)
    d = rules(rep, "nop-not-neutral")
    assert d and d[0].pass_name == "structural" and d[0].instr == n


def test_from_entries_normalizes_caesar_junk_fields():
    raw = np.zeros(2, dtype=PROG_DTYPE)
    raw["op"] = int(CaesarOp.ADD)
    raw["dest"] = (10, 11)
    raw["sval1"], raw["imm"], raw["mode"] = 7, -3, 2
    prog = Program.from_entries("caesar", 8, raw)
    assert (prog.entries["sval1"] == 0).all()
    assert (prog.entries["imm"] == 0).all()
    assert (prog.entries["mode"] == 0).all()
    assert (raw["sval1"] == 7).all()           # caller's array untouched
    rep = verify_program(prog, init_spans=((0, 4),))
    assert not rules(rep, "nonzero-carus-field")


# ---------------------------------------------------------------------------
# Structural pass: Carus
# ---------------------------------------------------------------------------

def test_carus_bad_opcode_flagged():
    lk = lower_golden("carus")
    lk.program.entries["op"][0] = len(isa.VOP_COMPACT) + 3
    rep = verify_lowered(lk)
    d = rules(rep, "bad-opcode")
    assert d and d[0].pass_name == "structural" and d[0].instr == 0


def test_carus_bad_mode_flagged():
    lk = lower_golden("carus")
    arith = int(isa.COMPACT_ID[VOp.VADD])
    row = int(np.flatnonzero(lk.program.entries["op"] == arith)[0])
    lk.program.entries["mode"][row] = 0x40
    rep = verify_lowered(lk)
    d = rules(rep, "bad-mode")
    assert d and d[0].pass_name == "structural" and d[0].instr == row


def test_carus_oob_register_direct_flagged():
    prog = kprog([carus_entry(VOp.VSETVL, sval1=4),
                  carus_entry(VOp.VADD, vd=N_REGS + 1, vs2=1, vs1=2)])
    rep = verify_program(prog, init_spans=((256, 8), (512, 8)))
    d = rules(rep, "oob-register")
    assert d and d[0].pass_name == "structural" and d[0].instr == 1
    assert "vd" in d[0].message


def test_carus_oob_register_indirect_flagged():
    e = carus_entry(VOp.VADD, 0, 0, 0,
                    mode=isa.MODE_INDIRECT | isa.MODE_VV,
                    sval2=((N_REGS + 1) << 16) | (1 << 8) | 2)
    prog = kprog([carus_entry(VOp.VSETVL, sval1=4), e])
    rep = verify_program(prog, init_spans=((256, 8), (512, 8)))
    d = rules(rep, "oob-register")
    assert d and d[0].instr == 1 and "vd" in d[0].message


def test_carus_vl_clamped_and_empty_warn():
    vlmax = 256 * (32 // 8)
    prog = kprog([carus_entry(VOp.VSETVL, sval1=vlmax + 1),
                  carus_entry(VOp.VSETVL, sval1=0)])
    rep = verify_program(prog)
    assert rules(rep, "vl-clamped")[0].instr == 0
    assert rules(rep, "vl-empty")[0].instr == 1
    assert rep.ok                       # warnings, not errors


def test_carus_nop_not_neutral_flagged():
    e = np.zeros((), dtype=PROG_DTYPE)
    e["op"] = isa.COMPACT_ID[VOp.VNOP]
    e["sval1"] = 3
    rep = verify_program(kprog([e]))
    assert rules(rep, "nop-not-neutral")[0].instr == 0


# ---------------------------------------------------------------------------
# Dataflow pass
# ---------------------------------------------------------------------------

def test_caesar_read_before_write_flagged():
    lk = lower_golden("caesar")
    # retarget one op's source at a word no load defines and no op writes
    lk.program.entries["src1"][0] = CAESAR_WORDS - 1
    rep = verify_lowered(lk)
    d = rules(rep, "read-before-write")
    assert d and d[0].pass_name == "dataflow" and d[0].instr == 0
    assert str(CAESAR_WORDS - 1) in d[0].message


def test_caesar_uncovered_store_flagged():
    lk = lower_golden("caesar")
    lo, nw = int(lk.out_slice[0]), int(lk.out_slice[1])
    # divert the write that covers the last output word
    row = int(np.flatnonzero(lk.program.entries["dest"] == lo + nw - 1)[-1])
    lk.program.entries["dest"][row] = lo + nw + 64
    rep = verify_lowered(lk)
    d = rules(rep, "uncovered-store")
    assert d and d[0].pass_name == "dataflow"
    assert str(lo + nw - 1) in d[0].message


def test_caesar_dead_write_warns_with_both_instrs():
    prog = cprog([caesar_entry(CaesarOp.ADD, 10, 0, 1),
                  caesar_entry(CaesarOp.ADD, 10, 0, 1)])
    rep = verify_program(prog, init_spans=((0, 2),), out_slice=(10, 1))
    d = rules(rep, "dead-write")
    assert d and d[0].severity == "warning" and d[0].instr == 0
    assert "instr#1" in d[0].message
    assert rep.ok and not rep.clean


def test_caesar_mac_chain_use_before_init():
    prog = cprog([caesar_entry(CaesarOp.MAC, 0, 0, 1),
                  caesar_entry(CaesarOp.MAC_STORE, 10, 0, 1)])
    rep = verify_program(prog, init_spans=((0, 2),), out_slice=(10, 1))
    d = rules(rep, "acc-use-before-init")
    assert [x.instr for x in d] == [0, 1]
    assert all(x.pass_name == "dataflow" for x in d)


def test_caesar_mac_chain_never_stored_warns():
    prog = cprog([caesar_entry(CaesarOp.MAC_INIT, 0, 0, 1),
                  caesar_entry(CaesarOp.MAC, 0, 0, 1),
                  caesar_entry(CaesarOp.ADD, 10, 0, 1)])
    rep = verify_program(prog, init_spans=((0, 2),), out_slice=(10, 1))
    assert rules(rep, "dead-accumulator")


def test_carus_vmacc_read_before_write_annotated():
    # VMACC reads its destination in place: an uninitialized vd is flagged
    # and annotated as the in-place accumulator hazard
    prog = kprog([carus_entry(VOp.VSETVL, sval1=4),
                  carus_entry(VOp.VMACC, vd=5, vs2=1, vs1=2)])
    rep = verify_program(prog, init_spans=((256, 8), (512, 8)))
    d = rules(rep, "read-before-write")
    assert any("VMACC" in x.message for x in d)
    assert any(x.instr == 1 for x in d)


def test_golden_kernels_verify_clean():
    for engine in ("caesar", "carus"):
        rep = verify_lowered(lower_golden(engine))
        assert rep.ok, rep.render()
        assert not rep.warnings, rep.render()


# ---------------------------------------------------------------------------
# Resource pass
# ---------------------------------------------------------------------------

def test_capacity_overflow_flagged():
    prog = cprog([caesar_entry(CaesarOp.ADD, 10, 0, 1)])
    rep = verify_program(prog, init_spans=((0, 2),), out_slice=(10, 1),
                         used_words=CAESAR_WORDS + 1)
    d = rules(rep, "capacity")
    assert d and d[0].pass_name == "resource" and d[0].severity == "error"


def test_resource_info_highwater_and_conflicts():
    # both operands in bank 0 -> one same-bank info record
    prog = cprog([caesar_entry(CaesarOp.ADD, 4096, 0, 1)])
    rep = verify_program(prog, init_spans=((0, 2),), out_slice=(4096, 1),
                         used_words=16)
    assert rep.clean
    infos = [d for d in rep.diagnostics if d.severity == "info"]
    assert any(d.rule == "mem-highwater" for d in infos)
    assert any(d.rule == "bank-conflicts" for d in infos)


# ---------------------------------------------------------------------------
# Partition safety
# ---------------------------------------------------------------------------

def slide_kernel():
    def slide_sum(t, x):
        a = t.load(x)
        t.store(a + a.slide_down(2), n=a.ne - 2)
    return frontend.jit(slide_sum, sew=8, check="off")


def test_wave_verifies_clean():
    k = slide_kernel()
    x = np.arange(64, dtype=np.int64)
    plan, lks = k.lower_wave(x, tiles=2)
    rep = verify_wave(k.trace(x), plan, lks, kernel="slide_sum")
    assert rep.ok, rep.render()


def test_store_not_partitioned_gap_flagged():
    k = slide_kernel()
    x = np.arange(64, dtype=np.int64)
    plan, lks = k.lower_wave(x, tiles=2)
    si, lo, hi = plan.pieces[0][0]
    plan.pieces[0][0] = (si, lo + 1, hi)       # open a one-element gap
    rep = verify_plan(k.trace(x), plan)
    d = rules(rep, "store-not-partitioned")
    assert d and d[0].pass_name == "partition"


def test_store_not_partitioned_overlap_flagged():
    k = slide_kernel()
    x = np.arange(64, dtype=np.int64)
    plan, lks = k.lower_wave(x, tiles=2)
    si, lo, hi = plan.pieces[1][0]
    plan.pieces[1][0] = (si, lo - 1, hi)       # overlap the previous shard
    rep = verify_plan(k.trace(x), plan)
    assert any("twice" in d.message
               for d in rules(rep, "store-not-partitioned"))


def test_insufficient_halo_flagged():
    k = slide_kernel()
    x = np.arange(64, dtype=np.int64)
    plan, lks = k.lower_wave(x, tiles=2)
    for b in plan.builders:                    # shrink every shard load
        for n in b.nodes:
            if n.op == "load":
                n.ne -= 2
    rep = verify_plan(k.trace(x), plan)
    d = rules(rep, "insufficient-halo")
    assert d and d[0].pass_name == "partition"


def test_wave_bucket_mismatch_flagged():
    k = slide_kernel()
    x = np.arange(64, dtype=np.int64)
    plan, lks = k.lower_wave(x, tiles=2)
    lks[1].pad_to(2 * lks[1].program.n_instr)  # split the wave's bucket
    rep = verify_wave(k.trace(x), plan, lks)
    assert rules(rep, "wave-bucket-mismatch")


# ---------------------------------------------------------------------------
# check= knob: eager validation + policy
# ---------------------------------------------------------------------------

def test_check_mode_validates_eagerly():
    with pytest.raises(ValueError, match="check mode"):
        frontend.jit(lambda t, x: t.store(t.load(x)), check="bogus")


def test_check_mode_default_is_error():
    assert golden.check == "error"


def test_check_error_raises_on_corrupt_program():
    report = CheckReport("t", [Diagnostic("error", "structural",
                                          "bad-opcode", "boom")])
    with pytest.raises(VerificationError) as ei:
        frontend._apply_report(report, "error")
    assert ei.value.report is report
    assert "bad-opcode" in str(ei.value)


def test_check_warn_warns_and_off_is_silent():
    report = CheckReport("t", [Diagnostic("warning", "dataflow",
                                          "dead-write", "w")])
    with pytest.warns(UserWarning, match="dead-write"):
        frontend._apply_report(report, "warn")
    frontend._apply_report(report, "off")      # no-op
    frontend._apply_report(report, "error")    # warnings don't raise


def test_lower_applies_check_mode():
    x = np.arange(64, dtype=np.int64)
    y = x[::-1].copy()
    for mode in ("error", "warn", "off"):
        lk = golden.lower(x, y, engine="caesar", check=mode)
        assert lk.program.n_instr > 0


# ---------------------------------------------------------------------------
# Dispatch-time asserts (pool / runtime hot path)
# ---------------------------------------------------------------------------

def test_assert_submittable_rejects_bad_opcode():
    prog = cprog([caesar_entry(CaesarOp.ADD, 10, 0, 1)])
    prog.entries["op"][0] = 63
    with pytest.raises(AssertionError, match="id space"):
        assert_submittable(prog)


def test_assert_wave_rejects_mixed_shapes():
    a = cprog([caesar_entry(CaesarOp.ADD, 10, 0, 1)])
    b = cprog([caesar_entry(CaesarOp.ADD, 10, 0, 1)] * 2)
    with pytest.raises(AssertionError, match="shape keys"):
        assert_wave([a, b])
    with pytest.raises(AssertionError, match="empty"):
        assert_wave([])
    assert_wave([a, a])                        # uniform wave passes


# ---------------------------------------------------------------------------
# Diagnostics plumbing
# ---------------------------------------------------------------------------

def test_diagnostic_str_includes_provenance():
    d = Diagnostic("error", "structural", "bad-opcode", "msg",
                   kernel="k", instr=4, op_index=2)
    s = str(d)
    assert "error[structural/bad-opcode]" in s
    assert "k instr#4 (traced op#2)" in s


def test_report_caps_per_rule():
    # one corrupted stream must not produce thousands of records
    ents = [caesar_entry(CaesarOp.ADD, 10, CAESAR_WORDS + i, 0)
            for i in range(check.MAX_PER_RULE + 5)]
    rep = verify_program(cprog(ents), init_spans=((0, 1),))
    d = rules(rep, "oob-address")
    assert len(d) == check.MAX_PER_RULE + 1
    assert "more" in d[-1].message


def test_cli_single_kernel_sweep():
    assert check.main(["--kernel", "xor", "--sew", "8", "--no-waves"]) == 0


# ---------------------------------------------------------------------------
# Properties: well-formed programs verify ok
# ---------------------------------------------------------------------------

@given(n_instr=st.integers(1, 24), seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_wellformed_caesar_fuzz_verifies_ok(n_instr, seed):
    """Structurally legal streams with complete MAC/DOT chains over a fully
    defined image produce no verifier errors (warnings like dead writes are
    legitimate in random programs)."""
    rng = np.random.default_rng(seed)
    binops = [CaesarOp.AND, CaesarOp.OR, CaesarOp.XOR, CaesarOp.ADD,
              CaesarOp.SUB, CaesarOp.MUL, CaesarOp.MIN, CaesarOp.MAX]
    entries = []
    while len(entries) < n_instr:
        if rng.random() < 0.25:                # a complete MAC chain
            init, body, store = (CaesarOp.MAC_INIT, CaesarOp.MAC,
                                 CaesarOp.MAC_STORE)
            entries.append(caesar_entry(init, 0, *rng.integers(0, 512, 2)))
            entries.append(caesar_entry(body, 0, *rng.integers(0, 512, 2)))
            entries.append(caesar_entry(store, int(rng.integers(0, 512)),
                                        *rng.integers(0, 512, 2)))
        else:
            entries.append(caesar_entry(binops[rng.integers(len(binops))],
                                        *rng.integers(0, 512, 3)))
    rep = verify_program(cprog(entries), init_spans=((0, 512),),
                         used_words=512)
    assert rep.ok, rep.render()


@given(n_instr=st.integers(1, 24), seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_wellformed_carus_fuzz_verifies_ok(n_instr, seed):
    rng = np.random.default_rng(seed)
    vlmax = 256 * (32 // 8)
    arith = list(isa.ARITH_OPS)
    entries = [carus_entry(VOp.VSETVL, sval1=int(rng.integers(1, vlmax + 1)))]
    for _ in range(n_instr):
        entries.append(carus_entry(
            arith[rng.integers(len(arith))],
            vd=int(rng.integers(N_REGS)), vs2=int(rng.integers(N_REGS)),
            vs1=int(rng.integers(N_REGS)),
            mode=int(rng.integers(2))))        # vv / vx, direct
    rep = verify_program(kprog(entries),
                         init_spans=((0, N_REGS * 256),))
    assert rep.ok, rep.render()


@pytest.mark.parametrize("sew", SEWS)
@pytest.mark.parametrize("name", ("xor", "relu", "matmul"))
def test_registry_lowered_kernels_verify_clean(name, sew):
    from repro.core import programs as P
    kb = P.build(name, sew)
    for engine in ("caesar", "carus"):
        eb = getattr(kb, engine, None)
        if eb is None:
            continue
        lk = getattr(eb, "lowered", None)
        rep = (verify_lowered(lk) if lk is not None
               else verify_program(eb.program))
        assert rep.ok, rep.render()
