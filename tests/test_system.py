"""End-to-end behaviour tests for the whole system: train -> checkpoint ->
quantize (the paper's technique) -> serve, plus dry-run/roofline plumbing."""

import numpy as np
import jax

from repro.configs import base as cb
from repro.data.pipeline import DataConfig
from repro.serve.engine import Request, ServeEngine, quantize_params
from repro.train.trainer import Trainer, TrainerConfig


def test_train_quantize_serve_pipeline(tmp_path):
    """The full production path: train a reduced model, checkpoint, convert
    to NMC int8 serving form, serve with continuous batching."""
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    tc = TrainerConfig(total_steps=20, ckpt_every=10, log_every=100,
                       ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(cfg, tc, data_cfg=DataConfig(global_batch=4, seq_len=64))
    out = tr.run()
    tr.checkpointer.close()
    assert out["final_step"] == 20
    loss = float(out["metrics"]["loss"])
    assert np.isfinite(loss)

    from repro.checkpoint import ckpt
    params0, opt0, _ = tr.init_state()
    state = ckpt.restore(str(tmp_path / "ck"), 20,
                         {"params": params0, "opt": opt0})
    params = state["params"]

    qcfg = cfg.scaled(nmc_mode="w8a8")
    qparams = quantize_params(params, qcfg)
    eng = ServeEngine(qcfg, qparams, n_slots=2, max_len=96)
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               6 + i).astype(np.int32),
                           max_new=5))
    done = eng.run()
    assert len(done) == 3
    for r in done:
        assert len(r.out) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_loss_decreases_over_training(tmp_path):
    from repro.optim import adamw
    cfg = cb.get("h2o-danube-1.8b", smoke=True)
    tc = TrainerConfig(total_steps=25, ckpt_every=1000, log_every=1000,
                       ckpt_dir=str(tmp_path / "ck"))
    # single repeated batch -> loss must drop substantially
    tr = Trainer(cfg, tc,
                 opt_cfg=adamw.AdamWConfig(lr=2e-3, warmup_steps=2,
                                           total_steps=25),
                 data_cfg=DataConfig(global_batch=4, seq_len=32))
    tr.dataset.batch_at = lambda step: tr.dataset.__class__.batch_at(
        tr.dataset, 0)    # freeze the stream
    out = tr.run()
    tr.checkpointer.close()
    first_loss = np.log(cfg.vocab_size)      # ~random-init cross entropy
    assert float(out["metrics"]["loss"]) < first_loss - 1.0


def test_roofline_pipeline_shapes():
    """flash_io_bytes must be positive exactly for attention archs/shapes."""
    from benchmarks.roofline import flash_io_bytes
    assert flash_io_bytes("mistral-nemo-12b", "train_4k") > 0
    assert flash_io_bytes("xlstm-125m", "train_4k") == 0.0
    assert flash_io_bytes("mistral-nemo-12b", "decode_32k") == 0.0
    assert flash_io_bytes("whisper-tiny", "prefill_32k") > 0


def test_input_specs_cover_all_cells():
    from repro.configs import applicable_shapes, get, SHAPES
    from repro.launch import specs as S
    for arch in cb.ARCH_IDS:
        cfg = get(arch)
        for sh in applicable_shapes(cfg):
            fn, args, donate = S.cell_fn_and_inputs(cfg, SHAPES[sh])
            leaves = jax.tree.leaves(args)
            assert leaves and all(hasattr(x, "shape") for x in leaves), \
                (arch, sh)
            # no device allocation: everything is abstract
            assert all(isinstance(x, jax.ShapeDtypeStruct)
                       for x in leaves), (arch, sh)
